"""Streaming-equivalence suite: partitioned counting must be bit-identical
to one-shot counting on the concatenated stream — for every engine, with
two-pass on and off, under splits that land mid-occurrence and on duplicate
timestamps (the tie-holdback and zone-inclusive boundary cases)."""

import numpy as np
import pytest

from repro.core import (EpisodeBatch, EventStream, StreamingA2Counter,
                        StreamingCounter, StreamingMiner, bucket_size,
                        count_a1, count_a1_sequential, count_a2,
                        count_a2_sequential, count_dispatch, count_level1,
                        count_two_pass, mine, mine_partitions,
                        type_histogram)
from repro.telemetry import ThroughputMeter

NUM_TYPES = 5


def tie_heavy_stream(seed, n=160):
    """Gaps drawn from {0, 0, 1, 2}: long runs of equal timestamps, so
    index-based splits routinely land inside a tie group."""
    rng = np.random.default_rng(seed)
    gaps = rng.choice([0, 0, 1, 2], size=n)
    times = (np.cumsum(gaps) + 1).astype(np.int32)
    types = rng.integers(0, NUM_TYPES, size=n).astype(np.int32)
    return EventStream(types, times, NUM_TYPES)


def batch():
    """Episodes with repeated types, zero lower bounds (tie-sensitive), and
    heterogeneous spans (exercises the inclusive τ+W stitch zone)."""
    return EpisodeBatch(
        np.int32([[0, 1, 2], [1, 2, 3], [2, 2, 0], [4, 0, 1]]),
        np.int32([[1, 0], [0, 2], [0, 0], [0, 0]]),
        np.int32([[5, 6], [4, 7], [3, 3], [6, 2]]))


def split_by_index(stream, k):
    n = stream.types.shape[0]
    cuts = [0] + [n * j // k for j in range(1, k)] + [n]
    return [EventStream(stream.types[a:b], stream.times[a:b],
                        stream.num_types)
            for a, b in zip(cuts[:-1], cuts[1:])]


@pytest.mark.parametrize("engine", ["ptpe", "mapconcatenate", "hybrid"])
@pytest.mark.parametrize("k", [1, 2, 3, 8])
def test_streaming_counter_equals_one_shot(engine, k):
    for seed in (0, 3):
        stream = tie_heavy_stream(seed)
        eps = batch()
        oracle = count_a1_sequential(stream, eps)
        ctr = StreamingCounter(eps, engine=engine)
        outs = list(ctr.run(split_by_index(stream, k)))
        np.testing.assert_array_equal(outs[-1], oracle)
        # and through update()/finalize()
        ctr2 = StreamingCounter(eps, engine=engine)
        for w in split_by_index(stream, k):
            ctr2.update(w)
        np.testing.assert_array_equal(ctr2.finalize(), oracle)


@pytest.mark.parametrize("lcap", [1, 2])
def test_run_snapshots_match_update_snapshots(lcap):
    """run()'s prefetch stages window p+1 (and records its history) before
    window p's counts are read; flagged-episode recounts must still cover
    exactly the consumed prefix, i.e. every intermediate snapshot equals the
    unpipelined update() path."""
    stream = tie_heavy_stream(2, n=200)
    eps = batch()
    wins = split_by_index(stream, 4)
    a = StreamingCounter(eps, engine="ptpe", lcap=lcap)
    piped = list(a.run(wins))
    b = StreamingCounter(eps, engine="ptpe", lcap=lcap)
    for i, w in enumerate(wins):
        np.testing.assert_array_equal(piped[i],
                                      b.update(w, final=i == len(wins) - 1))


@pytest.mark.parametrize("lcap", [1, 2])
def test_streaming_flagged_episodes_restored(lcap):
    """Tiny list capacities force live-eviction flags; streaming counts must
    still be exact via the history recount."""
    stream = tie_heavy_stream(1, n=200)
    eps = batch()
    oracle = count_a1_sequential(stream, eps)
    for engine in ("ptpe", "mapconcatenate"):
        ctr = StreamingCounter(eps, engine=engine, lcap=lcap)
        for w in split_by_index(stream, 3):
            ctr.update(w)
        np.testing.assert_array_equal(ctr.finalize(), oracle)


def test_streaming_a2_counter_equals_one_shot():
    for seed in (0, 5):
        stream = tie_heavy_stream(seed)
        eps = batch()
        want = count_a2_sequential(stream, eps.relaxed())
        for k in (1, 2, 3, 8):
            ctr = StreamingA2Counter(eps)
            for w in split_by_index(stream, k):
                out = ctr.update(w)
            np.testing.assert_array_equal(out, want)


def test_stateful_count_apis_chunked_equal_one_shot():
    stream = tie_heavy_stream(2)
    eps = batch()
    a1_one = count_a1(stream, eps, use_kernel=False)
    a2_one = count_a2(stream, eps, use_kernel=False)
    tp_one = count_two_pass(stream, eps, theta=2, use_kernel=False)
    # split at a strict time increase so per-chunk dup flags stay exact
    ok = np.nonzero(np.diff(stream.times) > 0)[0] + 1
    cut = int(ok[len(ok) // 2])
    chunks = [EventStream(stream.types[:cut], stream.times[:cut], NUM_TYPES),
              EventStream(stream.types[cut:], stream.times[cut:], NUM_TYPES)]
    st_a1 = st_a2 = st_tp = st_disp = None
    for ch in chunks:
        c_a1, st_a1 = count_a1(ch, eps, use_kernel=False, state=st_a1,
                               return_state=True)
        c_a2, st_a2 = count_a2(ch, eps, use_kernel=False, state=st_a2,
                               return_state=True)
        tp, st_tp = count_two_pass(ch, eps, theta=2, use_kernel=False,
                                   state=st_tp, return_state=True)
        c_d, st_disp = count_dispatch(ch, eps, engine="ptpe",
                                      use_kernel=False, state=st_disp,
                                      return_state=True)
    np.testing.assert_array_equal(c_a1, a1_one)
    np.testing.assert_array_equal(c_a2, a2_one)
    np.testing.assert_array_equal(c_d, a1_one)
    np.testing.assert_array_equal(tp.a2_counts, tp_one.a2_counts)
    np.testing.assert_array_equal(tp.survived, tp_one.survived)
    np.testing.assert_array_equal(tp.counts, tp_one.counts)


@pytest.mark.parametrize("two_pass", [True, False])
def test_streaming_miner_cumulative_equals_one_shot_mine(two_pass):
    from repro.data import embedded_chain_stream
    st = embedded_chain_stream(NUM_TYPES, [1, 2, 3], (2, 6),
                               num_occurrences=40, noise_events=400,
                               t_max=30_000, seed=11)
    for engine in ("hybrid", "mapconcatenate"):
        one = mine(st, intervals=[(2, 6)], theta=15, max_level=3,
                   engine=engine, two_pass=two_pass)
        miner = StreamingMiner([(2, 6)], 15, max_level=3, mode="cumulative",
                               engine=engine, two_pass=two_pass)
        wins = split_by_index(st, 3)
        for i, w in enumerate(wins):
            res = miner.update(w, final=i == len(wins) - 1)
        assert len(res.frequent) == len(one.frequent)
        for fa, fb, ca, cb in zip(res.frequent, one.frequent,
                                  res.counts, one.counts):
            np.testing.assert_array_equal(fa.etypes, fb.etypes)
            np.testing.assert_array_equal(fa.tlo, fb.tlo)
            np.testing.assert_array_equal(fa.thi, fb.thi)
            np.testing.assert_array_equal(ca, cb)


def test_mine_partitions_cumulative_final_window():
    """mine_partitions in cumulative mode over an exact partition (dedup
    off: the split may legally land on a timestamp tie) ends bit-identical
    to one-shot mine on the concatenation."""
    stream = tie_heavy_stream(4, n=300)
    one = mine(stream, intervals=[(0, 4)], theta=8, max_level=3)
    wins = split_by_index(stream, 4)
    results = list(mine_partitions(wins, [(0, 4)], 8, max_level=3,
                                   mode="cumulative", overlap_dedup=False))
    assert [i for i, _ in results] == list(range(4))
    res = results[-1][1]
    for fa, fb, ca, cb in zip(res.frequent, one.frequent,
                              res.counts, one.counts):
        np.testing.assert_array_equal(fa.etypes, fb.etypes)
        np.testing.assert_array_equal(ca, cb)


def test_mine_partitions_per_window_counts_boundary_spanners():
    """A single planted occurrence straddling the partition cut must be
    counted by the carried miner (in the window where it completes) and is
    invisible to the restart baseline."""
    # A@10 B@13 | C@16 with the cut between 13 and 16
    types = np.int32([0, 1, 2])
    times = np.int32([10, 13, 16])
    w1 = EventStream(types[:2], times[:2], 3)
    w2 = EventStream(types[2:], times[2:], 3)
    eps_counts = []
    for carry in (True, False):
        total = 0
        for _, res in mine_partitions([w1, w2], [(1, 5)], 1, max_level=3,
                                      carry=carry, two_pass=False):
            if len(res.frequent) >= 3 and res.frequent[2].M:
                hits = [tuple(e) for e in res.frequent[2].etypes.tolist()]
                if (0, 1, 2) in hits:
                    total += int(res.counts[2][hits.index((0, 1, 2))])
        eps_counts.append(total)
    assert eps_counts == [1, 0]  # carry sees the straddler, restart cannot


def test_count_level1_helper_matches_naive():
    stream = tie_heavy_stream(6)
    padded = stream.padded_to(256)
    hist = type_histogram(padded)
    naive = np.array([(stream.types == e).sum() for e in range(NUM_TYPES)],
                     np.int64)
    np.testing.assert_array_equal(hist, naive)
    ets = np.int32([3, 0, 0, 4])
    np.testing.assert_array_equal(count_level1(padded, ets), naive[ets])


def test_bucket_size_powers_of_two():
    assert bucket_size(0) == 128
    assert bucket_size(128) == 128
    assert bucket_size(129) == 256
    assert bucket_size(1000, minimum=32) == 1024


def test_streaming_counter_rejects_out_of_order_windows():
    eps = batch()
    ctr = StreamingCounter(eps, engine="ptpe")
    ctr.update(EventStream(np.int32([0, 1]), np.int32([5, 9]), NUM_TYPES))
    with pytest.raises(ValueError, match="partition"):
        ctr.update(EventStream(np.int32([2]), np.int32([3]), NUM_TYPES))


def test_throughput_meter_summary():
    m = ThroughputMeter()
    for n in (100, 200):
        m.start()
        m.stop(n)
    s = m.summary()
    assert s["windows"] == 2 and s["events"] == 300
    assert s["events_per_sec"] > 0 and s["steady_events_per_sec"] > 0
