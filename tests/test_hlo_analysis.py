"""HLO analyzer validation on jitted modules with known content.

These tests need >1 host device; they spawn a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count so the main test process
keeps its single-device view (per the dry-run isolation rule)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.hlo_analysis import analyze

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    w_sh = NamedSharding(mesh, P("data", "model"))
    x_sh = NamedSharding(mesh, P("data", None))

    TRIPS = 7

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return y.sum()

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    comp = jax.jit(f, in_shardings=(w_sh, x_sh)).lower(w, x).compile()
    s = analyze(comp.as_text())
    cost = comp.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older JAX: one dict per device
        cost = cost[0]
    print(json.dumps({
        "dot_flops": s.dot_flops,
        "collective_bytes": s.collective_bytes,
        "breakdown": s.collective_breakdown,
        "trips": s.while_trip_counts,
        "cost_flops": float(cost["flops"]),
    }))
""")


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_trip_counts_recovered(result):
    assert 7.0 in result["trips"].values()


def test_loop_corrected_dot_flops(result):
    # per-device dot: 2*32*64*256 = 1.048M per iteration, ×7 iterations.
    want = 2 * 32 * 64 * 256 * 7
    assert abs(result["dot_flops"] - want) / want < 0.05
    # and cost_analysis undercounts by ~the trip count (sanity: our premise)
    assert result["cost_flops"] < result["dot_flops"] / 3


def test_collectives_found(result):
    assert result["collective_bytes"] > 0
    kinds = set(result["breakdown"])
    assert "all-gather" in kinds or "all-reduce" in kinds
