"""Batched serving example: prefill a batch of prompts through the
attention-free falcon-mamba family (O(1)-state decode) and stream tokens.

  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import serve_batch

cfg = get_smoke_config("falcon_mamba_7b")
mesh = make_host_mesh()
with mesh:
    toks, stats = serve_batch(cfg, batch=4, prompt_len=32, gen=16,
                              mesh=mesh)
print(f"generated token grid {toks.shape}")
print(f"prefill {stats['prefill_s']*1e3:.0f} ms, "
      f"decode {stats['tok_per_s']:.1f} tok/s")
