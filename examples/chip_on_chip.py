"""Chip-on-chip, 2026 edition: one compute graph (an MoE LM) emits routing
events; the paper's mining engine consumes them in real time.

Part 1 — the bridge: run a reduced MoE model over a corpus with an
artificial regularity (a repeating token motif), capture each layer's
top-k expert choices as an event stream (repro.telemetry), and mine
frequent expert-routing episodes — "expert A at layer 0, then expert B at
layer 1 within 2 tokens" — the artificial-brain analogue of the paper's
syn-fire chains.

Part 2 — the service: the paper's actual loop is many electrode arrays
feeding one mining accelerator. Two synthetic MEA sessions (different
firing statistics, different partition windows) stream through the
multi-tenant mining service concurrently — cross-session batched scans,
bounded per-session memory — and each tenant's per-window frequent-episode
deltas are printed as they complete.

  PYTHONPATH=src python examples/chip_on_chip.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import mine
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.obs import TRACER, span
from repro.telemetry import decode_expert_episode, routing_events


def _span_s(name: str) -> float:
    """Wall seconds of the most recent completed span called ``name``."""
    return next(e.dur for e in reversed(TRACER.events()) if e.name == name)

# --- a small MoE with a biased router so routing has real structure
cfg = get_smoke_config("dbrx_132b")
cfg = dataclasses.replace(cfg, num_layers=2, num_experts=8, top_k=2,
                          name="moe-telemetry")
params = init_params(jax.random.PRNGKey(0), cfg)

# token stream with a motif: tokens [7, 11, 13] repeat every 16 positions
rng = np.random.default_rng(0)
T = 512
toks = rng.integers(0, cfg.vocab_size, size=T)
toks[::16], toks[1::16], toks[2::16] = 7, 11, 13
toks = jnp.asarray(toks[None, :], jnp.int32)  # [1, T]


def capture_routing(params, cfg: ModelConfig, tokens):
    """Forward the embedding through each block's router, recording top-k."""
    x = params["embed"][tokens].astype(jnp.bfloat16)
    out = []
    for j in range(cfg.period):
        stacked = params["blocks"]["scan"][j]
        for r in range(cfg.num_periods):
            p = jax.tree.map(lambda a: a[r], stacked)
            h = rms_norm(x, p["ln2"])
            logits = h.astype(jnp.float32) @ p["moe"]["router"]
            _, topi = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
            out.append(topi[0])  # [T, K]
    return jnp.stack(out)  # [L, T, K]


with span("example.capture_routing"):
    topk = np.asarray(capture_routing(params, cfg, toks))
    stream = routing_events(topk, cfg.num_experts, ticks_per_token=1)
print(f"captured {len(stream)} routing events over {T} tokens "
      f"({topk.shape[0]} layers × top-{cfg.top_k}) "
      f"in {_span_s('example.capture_routing'):.2f}s")

# mine expert cascades: within-3-token chains, inclusive of simultaneity
with span("example.mine_routing"):
    res = mine(stream, intervals=[(0, 3)], theta=int(T * 0.06), max_level=3)
lv = res.frequent[-1] if res.frequent[-1].M else res.frequent[-2]
order = np.argsort(-res.counts[len(res.frequent) - 1]) \
    if res.frequent[-1].M else np.argsort(-res.counts[-2])
print("top expert cascades (layer.expert → ...):")
shown = 0
for i in order[:5]:
    ep = lv.etypes[i]
    path = " → ".join("L{}e{}".format(*decode_expert_episode(int(t),
                                                             cfg.num_experts))
                      for t in ep)
    cnt = res.counts[len(res.frequent) - 1][i] if res.frequent[-1].M else \
        res.counts[-2][i]
    print(f"  {path}   ×{int(cnt)}")
    shown += 1
assert shown > 0
print(f"mined in {_span_s('example.mine_routing'):.2f}s")

# --- part 2: two electrode-array sessions through the mining service
from repro.data import partition_windows, sym26  # noqa: E402
from repro.service import MiningService, SessionConfig  # noqa: E402

print("\nmulti-tenant service: two MEA sessions, different windows")
svc = MiningService()
tenants = {}
for sid, seed, rate, window_ms in (("culture-a", 0, 20.0, 1000),
                                   ("culture-b", 1, 35.0, 2500)):
    stream, truth = sym26(seconds=6, rate_hz=rate, seed=seed)
    svc.create_session(sid, SessionConfig(
        intervals=((5, 10),), theta=3, max_level=3, window_ms=window_ms,
        history_limit=4))
    wins = list(partition_windows(stream, window_ms))
    tenants[sid] = wins
    print(f"  {sid}: {len(stream)} events at {rate:.0f} Hz, "
          f"{len(wins)} windows of {window_ms} ms "
          f"(planted chain {truth['short'][0]})")

# interleaved ingest — both cultures are mined concurrently, not in turn
with span("example.serve"):
    for j in range(max(len(w) for w in tenants.values())):
        for sid, wins in tenants.items():
            if j < len(wins):
                svc.ingest(sid, wins[j], final=j == len(wins) - 1)
        svc.pump()
        for sid in tenants:
            for d in svc.poll(sid):
                top = sorted(d.episodes(level=3), key=lambda ec: -ec[1])[:2]
                print(f"  {sid} window {d.window_idx}: "
                      f"{d.n_events} events, top 3-episodes {top}")

stats = svc.stats()
for sid in tenants:
    s = stats["sessions"][sid]
    print(f"  {sid}: {s['events_per_sec']:,.0f} ev/s sustained, "
          f"p99 window latency {s['p99_latency_s']*1e3:.0f} ms")
print(f"  batcher fused {stats['batcher']['fused_requests']} scans into "
      f"{stats['batcher']['batches']} device batches over "
      f"{_span_s('example.serve'):.2f}s; kernel fallbacks "
      f"{stats['kernel']['fallbacks']}")
assert all(svc.session(sid).windows_done == len(w)
           for sid, w in tenants.items())
