"""Quickstart: mine frequent episodes from a synthetic spike train.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import count_a1_sequential, mine
from repro.data import sym26

# 1. A 26-neuron culture, 20 s, with two planted causal chains.
stream, truth = sym26(seconds=20, seed=0)
chain, interval, n_planted = truth["short"]
print(f"{len(stream)} events; planted chain {chain} "
      f"with delays in {interval}, ~{n_planted} occurrences")

# 2. Mine all episodes up to 3 nodes with the two-pass engine
#    (A2 upper-bound cull -> exact A1, Hybrid PTPE/MapConcatenate mapping).
result = mine(stream, intervals=[interval], theta=int(n_planted * 0.6),
              max_level=3)
for stats in result.stats:
    print(f"  level {stats.level}: {stats.num_candidates} candidates "
          f"-> {stats.num_survived_a2} after A2 cull "
          f"-> {stats.num_frequent} frequent  ({stats.seconds*1e3:.0f} ms)")

# 3. The planted chain is recovered, with an exactly-correct count.
lv3 = result.frequent[2]
found = [tuple(e) for e in lv3.etypes.tolist()]
idx = found.index(tuple(chain))
exact = count_a1_sequential(stream, lv3.select([idx]))[0]
print(f"recovered {chain}: count={result.counts[2][idx]} "
      f"(sequential oracle: {exact})")
assert result.counts[2][idx] == exact

# 4. Reconstruct the circuit (the paper's Fig. 1 end goal): the planted
#    synapses dominate the excess-co-firing graph.
from repro.core import reconstruct  # noqa: E402
g = reconstruct(stream, result)
print("strongest inferred connections:")
for a, b, w, c in g.top_edges(4):
    print(f"  neuron {a} → neuron {b}   weight {w:.3f}  (count {c})")
