"""End-to-end training driver: a ~20M-param gemma-family model, a few
hundred steps on CPU, with checkpoint/restart + watchdog (kill it mid-run
and re-launch: it resumes from the last complete checkpoint).

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_smoke_config("gemma3_1b")
    cfg = dataclasses.replace(cfg, d_model=256, num_heads=8, head_dim=32,
                              d_ff=1024, num_layers=8, vocab_size=2048,
                              name="gemma3-mini-20m")
    print(f"{cfg.name}: {cfg.num_params()/1e6:.1f}M params")
    mesh = make_host_mesh()
    with mesh:
        _, _, losses = train_loop(cfg, steps=args.steps, batch=8, seq=64,
                                  ckpt_dir=args.ckpt_dir, ckpt_every=50,
                                  mesh=mesh, log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
